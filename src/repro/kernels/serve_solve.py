"""Pallas TPU kernels: the fused uniform-λ serve request path.

The serving hot path (``serve/server.py``) answers a k-request microbatch
at the resident damping λ₀ with Algorithm 1's cached-factor identity

    U = S·V ;  w = L⁻ᵀ L⁻¹ U ;  X = (V − Sᵀw) / λ₀

— two passes over the (n, m) score window plus n-sized triangular work.
Dispatched compositionally that is four XLA calls with the m-sized
intermediates U-producer/apply each re-negotiating HBM.  Here the whole
identity is ONE kernel invocation with a (2, m/bk) grid:

  phase 0 (cross pass): each (n, bk) tile of S accumulates its S·V
    contribution into an (n, k) fp32 VMEM scratch that stays resident
    across the whole pass; on the last tile the forward/back triangular
    substitution against the resident L runs *in-kernel* (Mosaic has no
    triangular-solve primitive — it is the same masked row-by-row vector
    formulation as ``cholesky.py``'s panel step), leaving w in a second
    resident scratch.
  phase 1 (apply pass): S streams through VMEM a second time and each
    (bk, k) tile of X = (V − Sᵀw)/λ₀ is written exactly once.

The factor tile, RHS tiles and both (n, k) intermediates are pinned in
VMEM for the whole microbatch; accumulation is fp32 regardless of the
window storage dtype (bf16 windows upcast per-tile inside the kernel).

``sv_cross_pallas`` / ``serve_apply_pallas`` are the two S passes as
standalone kernels — the building blocks the blocked and sharded
(``repro.dist``, per-slab inside ``shard_map``) serve paths reuse when a
psum must sit between the cross pass and the substitution.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams, SMEM as _SMEM

__all__ = ["serve_solve_pallas", "sv_cross_pallas", "serve_apply_pallas"]


def _trisolve(L, U):
    """w = L⁻ᵀ L⁻¹ U by masked row-by-row substitution (no lax.linalg in
    Mosaic). L: (n, n) fp32 lower-triangular; U: (n, k) fp32. O(n²k) VPU/MXU
    work in 2n sequential steps — negligible next to the O(n·m·k) passes."""
    n, k = U.shape

    def fwd(i, Y):
        # rows ≥ i of Y are still zero, so the full-row product only picks
        # up already-solved entries
        li = jax.lax.dynamic_slice(L, (i, 0), (1, n))             # (1, n)
        acc = jax.lax.dot_general(li, Y, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        ui = jax.lax.dynamic_slice(U, (i, 0), (1, k))
        dii = jax.lax.dynamic_slice(L, (i, i), (1, 1))
        return jax.lax.dynamic_update_slice(Y, (ui - acc) / dii, (i, 0))

    Y = jax.lax.fori_loop(0, n, fwd, jnp.zeros_like(U))

    def bwd(t, Wv):
        i = n - 1 - t
        ci = jax.lax.dynamic_slice(L, (0, i), (n, 1))             # col i
        acc = jax.lax.dot_general(ci, Wv, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        yi = jax.lax.dynamic_slice(Y, (i, 0), (1, k))
        dii = jax.lax.dynamic_slice(L, (i, i), (1, 1))
        return jax.lax.dynamic_update_slice(Wv, (yi - acc) / dii, (i, 0))

    return jax.lax.fori_loop(0, n, bwd, jnp.zeros_like(U))


def _serve_solve_kernel(s_ref, l_ref, v_ref, lam_ref, x_ref, u_ref, w_ref):
    p = pl.program_id(0)
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(p == 0)
    def _cross():
        @pl.when(j == 0)
        def _init():
            u_ref[...] = jnp.zeros_like(u_ref)

        u_ref[...] += jax.lax.dot_general(
            s_ref[...], v_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

        @pl.when(j == nj - 1)
        def _solve():
            w_ref[...] = _trisolve(l_ref[...].astype(jnp.float32), u_ref[...])

    @pl.when(p == 1)
    def _apply():
        stw = jax.lax.dot_general(                       # (bk, k): contract n
            s_ref[...], w_ref[...], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        inv_lam = 1.0 / lam_ref[0, 0]
        x_ref[...] = (v_ref[...].astype(jnp.float32) - stw) * inv_lam


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def serve_solve_pallas(S: jax.Array, L: jax.Array, V: jax.Array, lam,
                       *, bk: int = 512, interpret: bool = False) -> jax.Array:
    """X = (V − Sᵀ L⁻ᵀL⁻¹ S V)/λ.  S: (n, m); L: (n, n); V: (m, k) fp32.
    Returns (m, k) fp32. m % bk == 0 (zero pad is exact)."""
    n, m = S.shape
    k = V.shape[1]
    assert m % bk == 0, (m, bk)
    lam2 = jnp.asarray(lam, jnp.float32).reshape(1, 1)

    return pl.pallas_call(
        _serve_solve_kernel,
        grid=(2, m // bk),
        in_specs=[
            pl.BlockSpec((n, bk), lambda p, j: (0, j)),
            pl.BlockSpec((n, n), lambda p, j: (0, 0)),
            pl.BlockSpec((bk, k), lambda p, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda p, j: (0, 0), memory_space=_SMEM),
        ],
        out_specs=pl.BlockSpec((bk, k), lambda p, j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((n, k), jnp.float32),     # resident U = S·V
            pltpu.VMEM((n, k), jnp.float32),     # resident w = L⁻ᵀL⁻¹U
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
        name="serve_solve_fused",
    )(S, L, V.astype(jnp.float32), lam2)


def _sv_cross_kernel(s_ref, v_ref, u_ref):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        u_ref[...] = jnp.zeros_like(u_ref)

    u_ref[...] += jax.lax.dot_general(
        s_ref[...], v_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def sv_cross_pallas(S: jax.Array, V: jax.Array, *, bk: int = 512,
                    interpret: bool = False) -> jax.Array:
    """U = S @ V, fp32 accumulation into a single resident (n, k) tile.
    S: (n, m); V: (m, k). m % bk == 0."""
    n, m = S.shape
    k = V.shape[1]
    assert m % bk == 0, (m, bk)
    return pl.pallas_call(
        _sv_cross_kernel,
        grid=(m // bk,),
        in_specs=[
            pl.BlockSpec((n, bk), lambda j: (0, j)),
            pl.BlockSpec((bk, k), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((n, k), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
        name="serve_sv_cross",
    )(S, V.astype(jnp.float32))


def _serve_apply_kernel(s_ref, w_ref, v_ref, lam_ref, x_ref):
    stw = jax.lax.dot_general(
        s_ref[...], w_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    inv_lam = 1.0 / lam_ref[0, 0]
    x_ref[...] = (v_ref[...].astype(jnp.float32) - stw) * inv_lam


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def serve_apply_pallas(S: jax.Array, w: jax.Array, V: jax.Array, lam,
                       *, bk: int = 512, interpret: bool = False) -> jax.Array:
    """X = (V − Sᵀ @ w) / λ — the multi-RHS apply pass. S: (n, m);
    w: (n, k); V: (m, k). Returns (m, k) fp32. m % bk == 0."""
    n, m = S.shape
    k = V.shape[1]
    assert m % bk == 0, (m, bk)
    lam2 = jnp.asarray(lam, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _serve_apply_kernel,
        grid=(m // bk,),
        in_specs=[
            pl.BlockSpec((n, bk), lambda j: (0, j)),
            pl.BlockSpec((n, k), lambda j: (0, 0)),
            pl.BlockSpec((bk, k), lambda j: (j, 0)),
            pl.BlockSpec((1, 1), lambda j: (0, 0), memory_space=_SMEM),
        ],
        out_specs=pl.BlockSpec((bk, k), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
        name="serve_apply",
    )(S, w.astype(jnp.float32), V.astype(jnp.float32), lam2)
