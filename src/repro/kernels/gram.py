"""Pallas TPU kernel: tall-skinny Gram matrix  W = S · Sᵀ.

This is the paper's dominant cost term — O(n²·m) out of the total
O(n²·m + n³) — and the op the A100 implementation hands to cuBLAS. On TPU
we tile it for the MXU explicitly:

* grid = (n/bn, n/bn, m/bk); the K-reduction (parameter axis, the ~10⁶-long
  one) is the innermost, *sequential* grid dimension, so the (bn, bn) fp32
  accumulator tile is revisited in VMEM across the whole reduction and HBM
  sees exactly one read of S per output row-band and one write of W.
* both operands are row-bands of the *same* matrix S (blocks (i,k) and
  (j,k)) — the contraction is `dot_general` over the lane axis with
  ``preferred_element_type=float32``, the MXU's native bf16×bf16→fp32 mode.
* block sizes default to (bn=128 sublane-aligned, bk=512 lane-aligned);
  callers may tune. Inputs are padded in ``ops.py`` so every block is full.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

__all__ = ["gram_pallas", "gram_acc_pallas"]


def _gram_kernel(s_i_ref, s_j_ref, w_ref):
    """One (bn, bn) output tile; accumulates over the k (parameter) axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        w_ref[...] = jnp.zeros_like(w_ref)

    a = s_i_ref[...]
    b = s_j_ref[...]
    w_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def gram_pallas(S: jax.Array, *, bn: int = 128, bk: int = 512,
                interpret: bool = False) -> jax.Array:
    """W = S @ S.T with fp32 accumulation. S must be padded to (bn, bk) tiles.

    Returns (n, n) float32.
    """
    n, m = S.shape
    assert n % bn == 0 and m % bk == 0, (n, m, bn, bk)
    grid = (n // bn, n // bn, m // bk)

    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="gram_ssT",
    )(S, S)


def _gram_acc_kernel(w_in_ref, s_i_ref, s_j_ref, w_ref):
    """Like ``_gram_kernel`` but seeded from an incoming accumulator tile
    instead of zeros — the chaining primitive for blocked (per-layer) S."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        w_ref[...] = w_in_ref[...]

    a = s_i_ref[...]
    b = s_j_ref[...]
    w_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def gram_acc_pallas(S: jax.Array, W_in: jax.Array, *, bn: int = 128,
                    bk: int = 512, interpret: bool = False) -> jax.Array:
    """W = W_in + S @ S.T with fp32 accumulation; ``W_in`` is donated
    (aliased to the output), so chaining over B blocks keeps exactly one
    (n, n) accumulator live in HBM regardless of B.

    S must be padded to (bn, bk) tiles; W_in is (n, n) float32.
    """
    n, m = S.shape
    assert n % bn == 0 and m % bk == 0, (n, m, bn, bk)
    assert W_in.shape == (n, n) and W_in.dtype == jnp.float32, W_in
    grid = (n // bn, n // bn, m // bk)

    return pl.pallas_call(
        _gram_acc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        input_output_aliases={0: 0},
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="gram_ssT_acc",
    )(W_in, S, S)
