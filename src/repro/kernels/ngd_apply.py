"""Pallas TPU kernel: fused NGD apply  x = (v − Sᵀ·w) / λ.

The second (and final) pass over S in Algorithm 1. Fusing the GEMV, the
subtraction and the 1/λ scale means each (bk,)-block of v / x crosses HBM
exactly once and the m-length intermediate Sᵀw never materializes.

Layout note: S is stored (n, m) — samples × parameters — so the contraction
for x is over the *sublane* axis of each (n, bk) tile: tile_out(bk, 1) =
tileᵀ(bk, n) · w(n, 1), expressed as dot_general contracting dim 0 of the
tile, which Mosaic maps to an MXU pass with the transposed operand. n must
fit a single block (n ≤ ~4k fp32 in 16 MB VMEM alongside the accumulator);
``ops.py`` enforces this and falls back to XLA beyond it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams, SMEM as _SMEM

__all__ = ["ngd_apply_pallas"]


def _ngd_apply_kernel(s_ref, w_ref, v_ref, lam_ref, x_ref):
    s = s_ref[...]                      # (n, bk)
    w = w_ref[...]                      # (n, 1)
    stw = jax.lax.dot_general(          # (bk, 1) — contract the n axis
        s, w, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    inv_lam = 1.0 / lam_ref[0, 0]
    x_ref[...] = ((v_ref[...].astype(jnp.float32) - stw) * inv_lam
                  ).astype(x_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def ngd_apply_pallas(S: jax.Array, w: jax.Array, v: jax.Array, lam,
                     *, bk: int = 512, interpret: bool = False) -> jax.Array:
    """x = (v - S.T @ w) / lam.  S: (n, m); w: (n,); v: (m,). Returns (m,) f32."""
    n, m = S.shape
    assert m % bk == 0, (m, bk)
    lam2 = jnp.asarray(lam, jnp.float32).reshape(1, 1)
    w2 = w.reshape(n, 1).astype(jnp.float32)
    v2 = v.reshape(m, 1)

    x = pl.pallas_call(
        _ngd_apply_kernel,
        grid=(m // bk,),
        in_specs=[
            pl.BlockSpec((n, bk), lambda k: (0, k)),
            pl.BlockSpec((n, 1), lambda k: (0, 0)),
            pl.BlockSpec((bk, 1), lambda k: (k, 0)),
            pl.BlockSpec((1, 1), lambda k: (0, 0), memory_space=_SMEM),
        ],
        out_specs=pl.BlockSpec((bk, 1), lambda k: (k, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
        name="ngd_apply",
    )(S, w2, v2, lam2)
    return x[:, 0]
