"""Pallas TPU kernels for the paper's compute hot spots.

Kernels (each `<name>.py` has a ``pl.pallas_call`` with explicit BlockSpec
VMEM tiling; ``ops.py`` holds the jit'd wrappers; ``ref.py`` the pure-jnp
oracles):

* ``gram``      — tall-skinny W = S·Sᵀ, the O(n²m) dominant term.
* ``gram_sv``   — beyond-paper fusion (W, u) = (S·Sᵀ, S·v) in one pass.
* ``ngd_apply`` — fused x = (v − Sᵀw)/λ second pass.
* ``cholesky``  — blocked in-VMEM factorization (the paper's "chol" step).
* ``cholupdate`` — rank-k factor update/downdate L·Lᵀ ± X·Xᵀ (the
  streaming-curvature refresh, O(n²k) instead of re-factorizing).
* ``serve_solve`` — the whole cached uniform-λ serve request path
  (S·V cross pass → in-kernel triangular substitution against the
  resident L → (V − Sᵀw)/λ apply pass) in one invocation; ``sv_cross`` /
  ``serve_apply`` are the two S passes standalone for the blocked and
  sharded per-slab paths.
* ``fold_cols`` — fused fold cross columns (S·rows†, rows·rows†) feeding
  the ``replace_factors`` 2k-core of the FIFO window update.
* ``flash_attention`` — causal/windowed GQA attention forward (the model
  zoo's dominant compute op; online softmax in VMEM scratch).

Low-precision invariant: the window storage dtype is a free axis (fp32 or
bf16 — ``window_dtype`` on the serving stack), but every kernel and every
reference accumulates in fp32 (``preferred_element_type`` on the MXU,
explicit upcasts in jnp) and emits fp32 Gram/solve results. Only storage
narrows; arithmetic never does. fp8 window storage (following the
low-precision curvature literature in PAPERS.md) is the stretch goal —
the dtype plumbing is in place, blocked on accumulated-scale handling.
"""
from repro.kernels.ops import (
    chol_solve_fused,
    cholesky,
    cholupdate,
    flash_attention,
    fold_cols,
    gram,
    gram_sv,
    ngd_apply,
    on_tpu,
    serve_apply,
    serve_solve,
    sv_cross,
)

__all__ = ["chol_solve_fused", "cholesky", "cholupdate", "flash_attention",
           "fold_cols", "gram", "gram_sv", "ngd_apply", "on_tpu",
           "serve_apply", "serve_solve", "sv_cross"]
