"""Pallas TPU kernels for the paper's compute hot spots.

Kernels (each `<name>.py` has a ``pl.pallas_call`` with explicit BlockSpec
VMEM tiling; ``ops.py`` holds the jit'd wrappers; ``ref.py`` the pure-jnp
oracles):

* ``gram``      — tall-skinny W = S·Sᵀ, the O(n²m) dominant term.
* ``gram_sv``   — beyond-paper fusion (W, u) = (S·Sᵀ, S·v) in one pass.
* ``ngd_apply`` — fused x = (v − Sᵀw)/λ second pass.
* ``cholesky``  — blocked in-VMEM factorization (the paper's "chol" step).
* ``cholupdate`` — rank-k factor update/downdate L·Lᵀ ± X·Xᵀ (the
  streaming-curvature refresh, O(n²k) instead of re-factorizing).
* ``flash_attention`` — causal/windowed GQA attention forward (the model
  zoo's dominant compute op; online softmax in VMEM scratch).
"""
from repro.kernels.ops import (
    chol_solve_fused,
    cholesky,
    cholupdate,
    flash_attention,
    gram,
    gram_sv,
    ngd_apply,
    on_tpu,
)

__all__ = ["chol_solve_fused", "cholesky", "cholupdate", "flash_attention",
           "gram", "gram_sv", "ngd_apply", "on_tpu"]
