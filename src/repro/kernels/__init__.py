"""Pallas TPU kernels for the paper's compute hot spots.

Kernels (each `<name>.py` has a ``pl.pallas_call`` with explicit BlockSpec
VMEM tiling; ``ops.py`` holds the jit'd wrappers; ``ref.py`` the pure-jnp
oracles):

* ``gram``      — tall-skinny W = S·Sᵀ, the O(n²m) dominant term.
* ``gram_sv``   — beyond-paper fusion (W, u) = (S·Sᵀ, S·v) in one pass.
* ``ngd_apply`` — fused x = (v − Sᵀw)/λ second pass.
* ``cholesky``  — blocked in-VMEM factorization (the paper's "chol" step).
* ``flash_attention`` — causal/windowed GQA attention forward (the model
  zoo's dominant compute op; online softmax in VMEM scratch).
"""
from repro.kernels.ops import (
    chol_solve_fused,
    cholesky,
    flash_attention,
    gram,
    gram_sv,
    ngd_apply,
    on_tpu,
)

__all__ = ["chol_solve_fused", "cholesky", "flash_attention", "gram",
           "gram_sv", "ngd_apply", "on_tpu"]
