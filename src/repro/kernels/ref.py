"""Pure-jnp oracles for every Pallas kernel in this package.

Used by tests (``assert_allclose`` sweeps over shapes/dtypes) and as the
CPU execution path of ``ops.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["gram_ref", "gram_sv_ref", "ngd_apply_ref", "cholesky_ref",
           "cholupdate_ref", "chol_solve_ref"]

_HI = jax.lax.Precision.HIGHEST


def gram_ref(S: jax.Array) -> jax.Array:
    """W = S @ S.T in fp32."""
    S32 = S.astype(jnp.float32)
    return jnp.matmul(S32, S32.T, precision=_HI)


def gram_sv_ref(S: jax.Array, v: jax.Array):
    """(W, u) = (S@S.T, S@v) in fp32."""
    S32 = S.astype(jnp.float32)
    return (jnp.matmul(S32, S32.T, precision=_HI),
            jnp.matmul(S32, v.astype(jnp.float32), precision=_HI))


def ngd_apply_ref(S: jax.Array, w: jax.Array, v: jax.Array, lam) -> jax.Array:
    """x = (v - S.T @ w) / lam in fp32."""
    S32 = S.astype(jnp.float32)
    return (v.astype(jnp.float32)
            - jnp.matmul(S32.T, w.astype(jnp.float32), precision=_HI)
            ) / jnp.asarray(lam, jnp.float32)


def cholesky_ref(W: jax.Array) -> jax.Array:
    return jnp.linalg.cholesky(W.astype(jnp.float32))


def cholupdate_ref(L: jax.Array, X: jax.Array, sign: int = 1) -> jax.Array:
    """L' with L'·L'ᵀ = L·Lᵀ + sign·X·Xᵀ — the algorithmic home is
    ``repro.curvature.update`` (the complex-aware plane-rotation sweeps);
    this alias keeps the one-oracle-per-kernel convention of this module."""
    from repro.curvature.update import chol_downdate, chol_update
    fn = chol_update if sign > 0 else chol_downdate
    tgt = jnp.promote_types(jnp.promote_types(L.dtype, X.dtype), jnp.float32)
    return fn(L.astype(tgt), X.astype(tgt))


def chol_solve_ref(S: jax.Array, v: jax.Array, lam) -> jax.Array:
    """Full Algorithm 1 in fp32 — oracle for the kernel-composed solver."""
    from jax.scipy.linalg import solve_triangular
    W, u = gram_sv_ref(S, v)
    n = W.shape[0]
    L = jnp.linalg.cholesky(W + jnp.asarray(lam, jnp.float32) * jnp.eye(n))
    w = solve_triangular(L, u, lower=True)
    w = solve_triangular(L.T, w, lower=False)
    return ngd_apply_ref(S, w, v, lam)
