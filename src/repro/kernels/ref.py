"""Pure-jnp oracles for every Pallas kernel in this package.

Used by tests (``assert_allclose`` sweeps over shapes/dtypes) and as the
CPU execution path of ``ops.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["gram_ref", "gram_sv_ref", "ngd_apply_ref", "cholesky_ref",
           "cholupdate_ref", "chol_solve_ref", "sv_cross_ref",
           "serve_apply_ref", "serve_solve_ref", "fold_cols_ref"]

_HI = jax.lax.Precision.HIGHEST


def gram_ref(S: jax.Array) -> jax.Array:
    """W = S @ S.T in fp32."""
    S32 = S.astype(jnp.float32)
    return jnp.matmul(S32, S32.T, precision=_HI)


def gram_sv_ref(S: jax.Array, v: jax.Array):
    """(W, u) = (S@S.T, S@v) in fp32."""
    S32 = S.astype(jnp.float32)
    return (jnp.matmul(S32, S32.T, precision=_HI),
            jnp.matmul(S32, v.astype(jnp.float32), precision=_HI))


def ngd_apply_ref(S: jax.Array, w: jax.Array, v: jax.Array, lam) -> jax.Array:
    """x = (v - S.T @ w) / lam in fp32."""
    S32 = S.astype(jnp.float32)
    return (v.astype(jnp.float32)
            - jnp.matmul(S32.T, w.astype(jnp.float32), precision=_HI)
            ) / jnp.asarray(lam, jnp.float32)


def cholesky_ref(W: jax.Array) -> jax.Array:
    return jnp.linalg.cholesky(W.astype(jnp.float32))


def cholupdate_ref(L: jax.Array, X: jax.Array, sign: int = 1) -> jax.Array:
    """L' with L'·L'ᵀ = L·Lᵀ + sign·X·Xᵀ — the algorithmic home is
    ``repro.curvature.update`` (the complex-aware plane-rotation sweeps);
    this alias keeps the one-oracle-per-kernel convention of this module."""
    from repro.curvature.update import chol_downdate, chol_update
    fn = chol_update if sign > 0 else chol_downdate
    tgt = jnp.promote_types(jnp.promote_types(L.dtype, X.dtype), jnp.float32)
    return fn(L.astype(tgt), X.astype(tgt))


def _acc(*arrays):
    """fp32-or-wider accumulation dtype of the operands (the package-wide
    low-precision invariant: storage may be bf16, accumulation never is)."""
    tgt = jnp.float32
    for a in arrays:
        tgt = jnp.promote_types(tgt, a.dtype)
    return tgt


def _ct(A: jax.Array) -> jax.Array:
    """Conjugate transpose (plain transpose for real dtypes)."""
    return A.conj().T if jnp.issubdtype(A.dtype, jnp.complexfloating) \
        else A.T


def sv_cross_ref(S: jax.Array, V: jax.Array) -> jax.Array:
    """U = S @ V — the serve cross pass, fp32(+) accumulation."""
    tgt = _acc(S, V)
    return jnp.matmul(S.astype(tgt), V.astype(tgt), precision=_HI)


def serve_apply_ref(S: jax.Array, w: jax.Array, V: jax.Array, lam
                    ) -> jax.Array:
    """X = (V − S† @ w) / λ — the multi-RHS serve apply pass."""
    tgt = _acc(S, V, w)
    lam_r = jnp.asarray(lam, jnp.zeros((), tgt).real.dtype)
    return (V.astype(tgt)
            - jnp.matmul(_ct(S.astype(tgt)), w.astype(tgt), precision=_HI)
            ) / lam_r


def serve_solve_ref(S: jax.Array, L: jax.Array, V: jax.Array, lam
                    ) -> jax.Array:
    """The whole cached uniform-λ serve identity against a resident L:
    X = (V − S† L⁻† L⁻¹ S V)/λ — oracle for the fused serve kernel and the
    CPU execution path of ``ops.serve_solve`` (exact
    ``CholFactorization.solve`` algebra)."""
    from jax.scipy.linalg import solve_triangular
    u = sv_cross_ref(S, V)
    w = solve_triangular(L, u, lower=True)
    w = solve_triangular(_ct(L), w, lower=False)
    return serve_apply_ref(S, w, V, lam)


def fold_cols_ref(S: jax.Array, rows: jax.Array):
    """(cols, corner) = (S·rows†, rows·rows†) — the fold cross columns."""
    tgt = _acc(S, rows)
    r = rows.astype(tgt)
    return (jnp.matmul(S.astype(tgt), _ct(r), precision=_HI),
            jnp.matmul(r, _ct(r), precision=_HI))


def chol_solve_ref(S: jax.Array, v: jax.Array, lam) -> jax.Array:
    """Full Algorithm 1 in fp32 — oracle for the kernel-composed solver."""
    from jax.scipy.linalg import solve_triangular
    W, u = gram_sv_ref(S, v)
    n = W.shape[0]
    L = jnp.linalg.cholesky(W + jnp.asarray(lam, jnp.float32) * jnp.eye(n))
    w = solve_triangular(L, u, lower=True)
    w = solve_triangular(L.T, w, lower=False)
    return ngd_apply_ref(S, w, v, lam)
