"""Version-compat aliases for the Pallas TPU API surface.

jax renamed ``pltpu.TPUCompilerParams`` → ``pltpu.CompilerParams`` and
moved ``pltpu.SMEM`` → ``pltpu.MemorySpace.SMEM`` across 0.4 → 0.5+; the
kernels import the names from here so they run on either line.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

try:
    CompilerParams = pltpu.CompilerParams
except AttributeError:  # jax 0.4.x
    CompilerParams = pltpu.TPUCompilerParams

try:
    SMEM = pltpu.MemorySpace.SMEM
except AttributeError:  # jax 0.4.x
    SMEM = pltpu.SMEM

__all__ = ["CompilerParams", "SMEM"]
