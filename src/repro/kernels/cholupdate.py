"""Pallas TPU kernel: rank-k Cholesky update/downdate  L·Lᵀ ± X·Xᵀ.

The streaming-curvature hot op (``repro/curvature/update.py`` is the
pure-JAX reference). Same single-invocation in-VMEM regime as the blocked
``cholesky`` kernel — n is the sample count (10²–10³), so L (n, n) and
X (n, k) both fit VMEM and the whole rank-k sweep runs without touching
HBM in between:

  outer ``fori_loop`` over the k update columns; inner ``fori_loop`` over
  the n factor columns, each step one plane rotation (circular for the
  update, hyperbolic for the downdate) expressed as two length-n VPU
  vector ops:

      r = √(a² ± b²);  L[:, j] ← (a·L[:, j] ± b·x)/r;  x ← (a·x − b·L[:, j])/r

  No masking is needed: above the diagonal both operands are already zero,
  and x[j] cancels exactly (−b·a + a·b). O(n²·k) VPU FLOPs — negligible
  next to the O(n²·m) Gram it replaces, which is the whole point.

There is no triangular-solve or column-pivot primitive in Mosaic, which is
why the sweep is value-carried ``dynamic_slice`` arithmetic exactly like
``_chol_kernel``. Larger n falls back to the jnp reference in ``ops.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.cholesky import MAX_SINGLE_BLOCK_N

__all__ = ["cholupdate_pallas", "MAX_SINGLE_BLOCK_N"]


def _cholupdate_kernel(l_ref, x_ref, out_ref, *, sign: int, eps: float):
    L0 = l_ref[...].astype(jnp.float32)
    X = x_ref[...].astype(jnp.float32)
    n = L0.shape[0]
    k = X.shape[1]

    def col_sweep(t, L):
        x = jax.lax.dynamic_slice(X, (0, t), (n, 1))            # (n, 1)

        def rot(j, carry):
            L, x = carry
            col = jax.lax.dynamic_slice(L, (0, j), (n, 1))
            a = jax.lax.dynamic_slice(col, (j, 0), (1, 1))
            b = jax.lax.dynamic_slice(x, (j, 0), (1, 1))
            r = jnp.sqrt(jnp.maximum(a * a + sign * b * b, eps))
            new_col = (a * col + sign * b * x) / r
            x_new = (a * x - b * col) / r
            return jax.lax.dynamic_update_slice(L, new_col, (0, j)), x_new

        L, _ = jax.lax.fori_loop(0, n, rot, (L, x))
        return L

    L = jax.lax.fori_loop(0, k, col_sweep, L0)
    # FMA contraction makes the a·b − b·a cancellations inexact at the
    # 1-ulp level; pin the strict upper triangle back to exactly zero.
    rows = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    out_ref[...] = jnp.where(rows >= cols, L, 0.0).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("sign", "eps", "interpret"))
def cholupdate_pallas(L: jax.Array, X: jax.Array, *, sign: int = 1,
                      eps: float = 1e-30,
                      interpret: bool = False) -> jax.Array:
    """L' with L'·L'ᵀ = L·Lᵀ + sign·X·Xᵀ. Real fp32, L (n, n) lower,
    X (n, k); sign ∈ {+1, −1}. Zero columns of X are exact no-ops, so
    callers may pad k freely."""
    n = L.shape[0]
    assert L.shape == (n, n) and X.shape[0] == n, (L.shape, X.shape)
    assert sign in (1, -1), sign
    return pl.pallas_call(
        functools.partial(_cholupdate_kernel, sign=sign, eps=eps),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
        name="rank_k_cholupdate",
    )(L, X)
