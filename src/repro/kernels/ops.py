"""Jit'd public wrappers around the Pallas kernels.

Responsibilities:
* pad inputs to tile multiples (zero-padding is exact for all these ops),
* pick block sizes,
* route to the kernel on TPU, to ``interpret=True`` Pallas on CPU when
  explicitly requested (tests), and to the jnp reference otherwise,
* compose the kernels into the full Algorithm-1 solver
  (``chol_solve_fused``), the production entry point used by the NGD
  optimizer when kernels are enabled.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular

from repro.core.operator import BlockedScores, is_blocked
from repro.kernels import ref
from repro.kernels.cholesky import MAX_SINGLE_BLOCK_N, cholesky_pallas
from repro.kernels.cholupdate import cholupdate_pallas
from repro.kernels.fold import fold_cols_pallas
from repro.kernels.gram import gram_acc_pallas, gram_pallas
from repro.kernels.gram_sv import gram_sv_pallas
from repro.kernels.ngd_apply import ngd_apply_pallas
from repro.kernels.serve_solve import (serve_apply_pallas, serve_solve_pallas,
                                       sv_cross_pallas)

__all__ = ["gram", "gram_blocks", "gram_sv", "ngd_apply", "cholesky",
           "cholupdate", "chol_solve_fused", "flash_attention", "on_tpu",
           "pad_to", "sv_cross", "serve_apply", "serve_solve", "fold_cols"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _use_kernels(mode: Optional[str]) -> bool:
    """mode: None → auto (TPU only); 'interpret' → yes via interpreter;
    'kernel' → yes (compiled); 'ref' → no."""
    if mode == "ref":
        return False
    if mode in ("interpret", "kernel"):
        return True
    return on_tpu()


def pad_to(x: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    """Zero-pad trailing dims of x up to multiples of ``mults``."""
    pads = []
    for dim, mult in zip(x.shape, mults):
        pads.append((0, (-dim) % mult))
    if not any(p[1] for p in pads):
        return x
    return jnp.pad(x, pads)


def _pad_identity(W: jax.Array, mult: int) -> jax.Array:
    """Embed a square matrix in the top-left of the next ``mult``-multiple
    size, with ones on the padded diagonal — exact for Cholesky-shaped ops
    (the padded block factors/updates to itself)."""
    n = W.shape[0]
    npad = (-n) % mult
    if not npad:
        return W
    Wp = jnp.zeros((n + npad, n + npad), W.dtype)
    Wp = Wp.at[:n, :n].set(W)
    return Wp.at[jnp.arange(n, n + npad), jnp.arange(n, n + npad)].set(1.0)


def _pick_blocks(n: int, m: int) -> tuple[int, int]:
    bn = min(128, max(8, n))            # sublane-aligned output tile
    bk = 512 if m >= 512 else max(128, m)
    return bn, bk


def gram(S, *, mode: Optional[str] = None) -> jax.Array:
    """W = S@S.T (fp32) via the Pallas kernel (padded), else the reference.
    A blocked operator routes to the chained per-block kernel."""
    if is_blocked(S):
        return gram_blocks(S, mode=mode)
    if not _use_kernels(mode):
        return ref.gram_ref(S)
    n, m = S.shape
    bn, bk = _pick_blocks(n, m)
    Sp = pad_to(S, (bn, bk))
    W = gram_pallas(Sp, bn=bn, bk=bk, interpret=(mode == "interpret"))
    return W[:n, :n]


def gram_blocks(S, *, mode: Optional[str] = None) -> jax.Array:
    """W = Σ_b S_b @ S_bᵀ over per-layer blocks, fp32.

    Kernel path: the first block runs the zero-init Gram kernel; every
    further block runs ``gram_acc_pallas``, whose accumulator input is
    aliased to its output — one (n, n) fp32 buffer is threaded through the
    whole chain, so HBM traffic is one read of each block plus a single
    resident accumulator, never a flat (n, m) concatenation.
    """
    if hasattr(S, "materialize"):
        S = S.materialize()
    blocks = S.blocks if isinstance(S, BlockedScores) else tuple(S)
    n = blocks[0].shape[0]
    if not _use_kernels(mode):
        W = None
        for b in blocks:
            Wb = ref.gram_ref(b)
            W = Wb if W is None else W + Wb
        return W
    interp = (mode == "interpret")
    bn = min(128, max(8, n))
    np_ = n + ((-n) % bn)
    W = None
    for b in blocks:
        _, bk = _pick_blocks(n, b.shape[1])
        bp = pad_to(b, (bn, bk))
        if W is None:
            W = gram_pallas(bp, bn=bn, bk=bk, interpret=interp)
        else:
            W = gram_acc_pallas(bp, W, bn=bn, bk=bk, interpret=interp)
        assert W.shape == (np_, np_)
    return W[:n, :n]


def gram_sv(S: jax.Array, v: jax.Array, *, mode: Optional[str] = None):
    """(W, u) = (S@S.T, S@v) fused single pass."""
    if not _use_kernels(mode):
        return ref.gram_sv_ref(S, v)
    n, m = S.shape
    bn, bk = _pick_blocks(n, m)
    Sp = pad_to(S, (bn, bk))
    vp = pad_to(v.reshape(m), (bk,))
    W, u = gram_sv_pallas(Sp, vp, bn=bn, bk=bk,
                          interpret=(mode == "interpret"))
    return W[:n, :n], u[:n]


def ngd_apply(S: jax.Array, w: jax.Array, v: jax.Array, lam,
              *, mode: Optional[str] = None) -> jax.Array:
    """x = (v - S.T@w)/lam."""
    if not _use_kernels(mode):
        return ref.ngd_apply_ref(S, w, v, lam)
    n, m = S.shape
    _, bk = _pick_blocks(n, m)
    Sp = pad_to(S, (1, bk))
    vp = pad_to(v.reshape(m), (bk,))
    x = ngd_apply_pallas(Sp, w, vp, lam, bk=bk,
                         interpret=(mode == "interpret"))
    return x[:m]


def cholesky(W: jax.Array, *, mode: Optional[str] = None,
             panel: int = 16) -> jax.Array:
    """L = chol(W). Pallas single-block kernel for n ≤ MAX_SINGLE_BLOCK_N
    (padded with an identity diagonal to a panel multiple), XLA beyond."""
    n = W.shape[0]
    if not _use_kernels(mode) or n > MAX_SINGLE_BLOCK_N:
        return ref.cholesky_ref(W)
    Wp = _pad_identity(W, panel)
    L = cholesky_pallas(Wp, panel=panel, interpret=(mode == "interpret"))
    return L[:n, :n]


def cholupdate(L: jax.Array, X: jax.Array, *, sign: int = 1,
               mode: Optional[str] = None) -> jax.Array:
    """Rank-k factor refresh: L' with L'·L'ᵀ = L·Lᵀ + sign·X·Xᵀ.

    Same dispatch policy as ``cholesky``: the in-VMEM Pallas kernel for
    real fp32 factors up to MAX_SINGLE_BLOCK_N (padded with an identity
    diagonal — the extra rotations are exact no-ops), the pure-JAX
    reference (``repro.curvature.update``) beyond, on CPU, and for complex
    Hermitian factors (Mosaic has no complex arithmetic).
    """
    from repro.curvature.update import chol_downdate, chol_update

    fallback = chol_update if sign > 0 else chol_downdate
    n = L.shape[0]
    if X.ndim == 1:
        X = X[:, None]
    if (not _use_kernels(mode) or n > MAX_SINGLE_BLOCK_N
            or jnp.issubdtype(jnp.promote_types(L.dtype, X.dtype),
                              jnp.complexfloating)):
        return fallback(L, X)
    Lp = _pad_identity(L.astype(jnp.float32), 8)
    Xp = jnp.pad(X, ((0, Lp.shape[0] - n), (0, 0)))
    Lout = cholupdate_pallas(Lp, Xp.astype(jnp.float32),
                             sign=1 if sign > 0 else -1,
                             interpret=(mode == "interpret"))
    return Lout[:n, :n]


def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    mode: Optional[str] = None, bq=128, bk=128):
    """Model-layout adapter for the Pallas flash-attention kernel.

    q: (B, Tq, H, hd); k, v: (B, Tk, KH, hd), H % KH == 0. Routes to the
    kernel on TPU (or interpret mode); otherwise to the pure-jnp blockwise
    implementation in models/layers (identical math).
    """
    B, Tq, H, hd = q.shape
    _, Tk, KH, _ = k.shape
    if not _use_kernels(mode):
        from repro.models.layers import flash_attention as ref_attn
        return ref_attn(q, k, v, causal=causal, window=window, scale=scale)

    from repro.kernels.flash_attention import flash_attention_pallas
    g = H // KH
    bq_, bk_ = min(bq, Tq), min(bk, Tk)
    pad_q = (-Tq) % bq_
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Tq, hd)
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    assert Tk % bk_ == 0, (Tk, bk_)      # KV padding would pollute softmax
    kf = k.transpose(0, 2, 1, 3).reshape(B * KH, Tk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KH, Tk, hd)
    o = flash_attention_pallas(qf, kf, vf, causal=causal, window=window,
                               scale=scale, group=g, bq=bq_, bk=bk_,
                               interpret=(mode == "interpret"))
    o = o[:, :Tq].reshape(B, H, Tq, hd).transpose(0, 2, 1, 3)
    return o


def _any_complex(*arrays) -> bool:
    return any(jnp.issubdtype(a.dtype, jnp.complexfloating) for a in arrays)


def sv_cross(S: jax.Array, V, *, mode: Optional[str] = None):
    """U = S @ V with fp32(+) accumulation — the serve cross pass over one
    window block (complex windows route to the reference; Mosaic has no
    complex arithmetic)."""
    squeeze = V.ndim == 1
    V2 = V[:, None] if squeeze else V
    if not _use_kernels(mode) or _any_complex(S, V2):
        u = ref.sv_cross_ref(S, V2)
    else:
        n, m = S.shape
        _, bk = _pick_blocks(n, m)
        Sp = pad_to(S, (1, bk))
        Vp = pad_to(V2, (bk, 1))
        u = sv_cross_pallas(Sp, Vp, bk=bk, interpret=(mode == "interpret"))
    return u[:, 0] if squeeze else u


def serve_apply(S: jax.Array, w, V, lam, *, mode: Optional[str] = None):
    """X = (V − S†·w)/λ — the multi-RHS apply pass over one window block."""
    squeeze = V.ndim == 1
    V2 = V[:, None] if squeeze else V
    w2 = w[:, None] if w.ndim == 1 else w
    if not _use_kernels(mode) or _any_complex(S, V2, w2):
        x = ref.serve_apply_ref(S, w2, V2, lam)
    else:
        n, m = S.shape
        _, bk = _pick_blocks(n, m)
        Sp = pad_to(S, (1, bk))
        Vp = pad_to(V2, (bk, 1))
        x = serve_apply_pallas(Sp, w2, Vp, lam, bk=bk,
                               interpret=(mode == "interpret"))[:m]
    return x[:, 0] if squeeze else x


def serve_solve(S, L, V, lam, *, mode: Optional[str] = None):
    """The whole cached uniform-λ request path against a resident factor:

        X = (V − Sᵀ L⁻ᵀ L⁻¹ S V) / λ

    Dense real windows up to MAX_SINGLE_BLOCK_N run the single fused
    kernel (both S passes + in-kernel substitution, one invocation);
    blocked windows compose the ``sv_cross``/``serve_apply`` passes per
    block with the n-sized triangular work in XLA; complex windows and the
    CPU backend take the reference — identical algebra throughout. Returns
    fp32 (m, k), matching the input's flat/blocked form."""
    if is_blocked(S) or isinstance(V, (tuple, list)):
        return _serve_solve_blocked(S, L, V, lam, mode=mode)
    squeeze = V.ndim == 1
    V2 = V[:, None] if squeeze else V
    n, m = S.shape
    if (not _use_kernels(mode) or _any_complex(S, L, V2)
            or n > MAX_SINGLE_BLOCK_N):
        x = ref.serve_solve_ref(S, L, V2, lam)
    else:
        _, bk = _pick_blocks(n, m)
        Sp = pad_to(S, (1, bk))
        Vp = pad_to(V2, (bk, 1))
        x = serve_solve_pallas(Sp, L, Vp, lam, bk=bk,
                               interpret=(mode == "interpret"))[:m]
    return x[:, 0] if squeeze else x


def _serve_solve_blocked(S, L, V, lam, *, mode: Optional[str] = None):
    from repro.core.operator import as_blocked_vector

    if hasattr(S, "materialize"):
        S = S.materialize()
    v_blocks, was_flat = as_blocked_vector(S, V)
    u = None
    for b, vb in zip(S.blocks, v_blocks):
        ub = sv_cross(b, vb, mode=mode)
        u = ub if u is None else u + ub
    w = solve_triangular(L, u, lower=True)
    Lt = L.conj().T if jnp.issubdtype(L.dtype, jnp.complexfloating) else L.T
    w = solve_triangular(Lt, w, lower=False)
    x = tuple(serve_apply(b, w, vb, lam, mode=mode)
              for b, vb in zip(S.blocks, v_blocks))
    return BlockedScores.concat(x) if was_flat else x


def fold_cols(S, rows, *, mode: Optional[str] = None):
    """(cols, corner) = (S·rows†, rows·rows†) — the fold cross pass, fused
    per window block with both fp32 accumulators resident in VMEM. ``S``
    dense or blocked; ``rows`` (k, m) dense or matching per-block tuple."""
    S_blocks = S.blocks if is_blocked(S) else (S,)
    row_blocks = tuple(rows) if isinstance(rows, (tuple, list)) else (rows,)
    cols = corner = None
    for b, r in zip(S_blocks, row_blocks):
        if not _use_kernels(mode) or _any_complex(b, r):
            cb, kb = ref.fold_cols_ref(b, r)
        else:
            n, m = b.shape
            _, bk = _pick_blocks(n, m)
            bp = pad_to(b, (1, bk))
            rp = pad_to(r, (1, bk))
            cb, kb = fold_cols_pallas(bp, rp, bk=bk,
                                      interpret=(mode == "interpret"))
        cols = cb if cols is None else cols + cb
        corner = kb if corner is None else corner + kb
    return cols, corner


def chol_solve_fused(S, v, damping, *, mode: Optional[str] = None):
    """Algorithm 1 composed entirely from the Pallas kernels:

        (W, u) = gram_sv(S, v)          # fused single pass over S
        L      = cholesky(W + λĨ)       # in-VMEM blocked factorization
        w      = L⁻ᵀ L⁻¹ u              # XLA triangular solves (n×n, tiny)
        x      = ngd_apply(S, w, v, λ)  # fused second pass over S

    With a blocked S the same composition runs per block: (W, u)
    contributions accumulate across blocks, then the apply runs block by
    block — ``v`` may be flat or a tuple of per-block pieces and the
    result comes back in the same form.
    """
    if is_blocked(S):
        return _chol_solve_fused_blocked(S, v, damping, mode=mode)
    n = S.shape[0]
    lam = jnp.asarray(damping, jnp.float32)
    W, u = gram_sv(S, v, mode=mode)
    L = cholesky(W + lam * jnp.eye(n, dtype=W.dtype), mode=mode)
    w = solve_triangular(L, u, lower=True)
    w = solve_triangular(L.T, w, lower=False)
    return ngd_apply(S, w, v, lam, mode=mode)


def _chol_solve_fused_blocked(S, v, damping, *, mode: Optional[str] = None):
    from repro.core.operator import as_blocked_vector

    if hasattr(S, "materialize"):
        S = S.materialize()
    v_blocks, was_flat = as_blocked_vector(S, v)
    n = S.n
    lam = jnp.asarray(damping, jnp.float32)

    W, u = None, None
    for b, vb in zip(S.blocks, v_blocks):
        Wb, ub = gram_sv(b, vb, mode=mode)
        W = Wb if W is None else W + Wb
        u = ub if u is None else u + ub
    L = cholesky(W + lam * jnp.eye(n, dtype=W.dtype), mode=mode)
    w = solve_triangular(L, u, lower=True)
    w = solve_triangular(L.T, w, lower=False)
    x = tuple(ngd_apply(b, w, vb, lam, mode=mode)
              for b, vb in zip(S.blocks, v_blocks))
    return BlockedScores.concat(x) if was_flat else x
