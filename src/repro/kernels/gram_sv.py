"""Pallas TPU kernel: fused  (W, u) = (S·Sᵀ, S·v)  in ONE pass over S.

Beyond-paper optimization. Algorithm 1 reads S three times from HBM:
once for the Gram, once for u = S·v, once for the apply Sᵀw. The Gram and
the matvec share the identical S traffic pattern, so we fuse them: while a
(bn, bk) tile of S is resident in VMEM for the Gram accumulation, the same
tile also accumulates its u contribution. S-traffic for the whole solve
drops from 3·n·m to 2·n·m words (the apply's re-read is unavoidable — it
needs w, which depends on the full Gram).

The u accumulation fires only on the j == 0 column of the output grid so
each (i, k) tile contributes exactly once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

__all__ = ["gram_sv_pallas"]


def _gram_sv_kernel(s_i_ref, s_j_ref, v_ref, w_ref, u_ref):
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init_w():
        w_ref[...] = jnp.zeros_like(w_ref)

    a = s_i_ref[...]
    w_ref[...] += jax.lax.dot_general(
        a, s_j_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    # u tile (bn, 1): accumulate once per (i, k) — gate on j == 0.
    @pl.when(jnp.logical_and(j == 0, k == 0))
    def _init_u():
        u_ref[...] = jnp.zeros_like(u_ref)

    @pl.when(j == 0)
    def _acc_u():
        u_ref[...] += jax.lax.dot_general(
            a, v_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def gram_sv_pallas(S: jax.Array, v: jax.Array, *, bn: int = 128,
                   bk: int = 512, interpret: bool = False):
    """Returns (W, u) = (S@S.T, S@v), both fp32. v is (m,) or (m, 1)."""
    n, m = S.shape
    assert n % bn == 0 and m % bk == 0, (n, m, bn, bk)
    squeeze = v.ndim == 1
    v2 = v[:, None] if squeeze else v
    grid = (n // bn, n // bn, m // bk)

    W, u = pl.pallas_call(
        _gram_sv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bk, 1), lambda i, j, k: (k, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bn, 1), lambda i, j, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, n), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
        name="gram_sv_fused",
    )(S, S, v2.astype(S.dtype))
    return W, (u[:, 0] if squeeze else u)
