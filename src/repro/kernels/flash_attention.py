"""Pallas TPU kernel: flash-attention forward (causal / windowed, GQA).

The attention score computation is the dominant FLOP term of every
assigned transformer architecture; this kernel gives it the canonical
TPU treatment:

* grid = (B·H, Tq/bq, Tk/bk) with the KV axis innermost and *sequential*;
  the (bq, hd) fp32 accumulator and the (bq,) running max / sum live in
  VMEM scratch that persists across the KV sweep (online softmax — HBM
  never sees a (Tq, Tk) tensor);
* GQA without materializing repeated KV: the K/V BlockSpec index maps
  divide the query-head grid index by the group size, so each KV head's
  tile is streamed once per query-head group directly from HBM;
* causal / sliding-window masking is applied from block-relative iotas,
  and fully-masked KV blocks are skipped with ``pl.when`` (≈2× fewer MXU
  passes for causal attention);
* bf16 QK/PV operands, fp32 softmax statistics — matching the framework's
  ``attn_bf16`` lever.

``ops.py`` routes to this kernel on TPU; the pure-jnp blockwise
implementation in ``models/layers.py`` (same math, validated against the
naive oracle) remains the CPU/compile-analysis path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

__all__ = ["flash_attention_pallas"]

F32 = jnp.float32
NEG = -0.7 * float(jnp.finfo(jnp.float32).max)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window, bq: int, bk: int,
                  nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos0 = qi * bq
    k_pos0 = ki * bk
    # a KV block is live unless it is entirely above the causal diagonal
    # or entirely outside the sliding window
    live = True
    if causal:
        live = jnp.logical_and(live, k_pos0 <= q_pos0 + bq - 1)
    if window is not None:
        live = jnp.logical_and(live, k_pos0 + bk - 1 > q_pos0 - window)

    @pl.when(live)
    def _block():
        q = q_ref[0]                                   # (bq, hd)
        k = k_ref[0]                                   # (bk, hd)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=F32) * scale        # (bq, bk)

        q_pos = q_pos0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_pos0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])                # (bq, bk) f32
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = corr * l_scr[...] + jnp.sum(p, axis=1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=F32)                # (bq, hd)
        acc_scr[...] = corr[:, None] * acc_scr[...] + pv
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _final():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "bq", "bk", "group", "interpret"))
def flash_attention_pallas(q, k, v, *, causal=True, window=None, scale=None,
                           group=1, bq=128, bk=128, interpret=False):
    """q: (BH, Tq, hd); k, v: (BKH, Tk, hd) with BH == BKH·group.

    Returns (BH, Tq, hd) in q.dtype. Tq % bq == Tk % bk == 0 (pad upstream;
    ops.py handles the padding and the (B, T, H, hd) layout).
    """
    BH, Tq, hd = q.shape
    BKH, Tk, _ = k.shape
    assert BH == BKH * group, (BH, BKH, group)
    assert Tq % bq == 0 and Tk % bk == 0, (Tq, bq, Tk, bk)
    scale = scale if scale is not None else hd ** -0.5
    nq, nk = Tq // bq, Tk // bk

    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, nk=nk),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Tq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), F32),
            pltpu.VMEM((bq,), F32),
            pltpu.VMEM((bq, hd), F32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="flash_attention_fwd",
    )(q, k, v)
