"""Pallas TPU kernel: fused fold cross-columns for the FIFO window update.

One online-adaptation fold (``serve/adapt.py``) replaces the k oldest
window samples and needs the new Gram columns

    cols   = S · rows†        (n, k)  — the only m-sized work of the fold
    corner = rows · rows†     (k, k)  — the replaced rows' own entries

before the 2k-core ``replace_factors`` split (which stays in XLA: its
2k×2k eigendecomposition has no Mosaic lowering, and it is m-free).
Compositionally those are two separate passes over ``rows``; fused, each
(n, bk) tile of S and (k, bk) tile of rows crosses HBM once and both
fp32 accumulators stay resident in VMEM across the whole m sweep —
regardless of the window storage dtype (bf16 tiles upcast on the MXU).

The rows must already be rounded to the window storage dtype when they
arrive (``serve/adapt.pad_to_window_cols`` is the single cast point), so
the columns describe exactly the values the FIFO write will store.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

__all__ = ["fold_cols_pallas"]


def _fold_cols_kernel(s_ref, r_ref, cols_ref, corner_ref):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        cols_ref[...] = jnp.zeros_like(cols_ref)
        corner_ref[...] = jnp.zeros_like(corner_ref)

    r = r_ref[...]
    cols_ref[...] += jax.lax.dot_general(
        s_ref[...], r, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    corner_ref[...] += jax.lax.dot_general(
        r, r, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def fold_cols_pallas(S: jax.Array, rows: jax.Array, *, bk: int = 512,
                     interpret: bool = False):
    """(cols, corner) = (S @ rowsᵀ, rows @ rowsᵀ), both fp32.
    S: (n, m); rows: (k, m). m % bk == 0 (zero pad is exact)."""
    n, m = S.shape
    k = rows.shape[0]
    assert rows.shape[1] == m and m % bk == 0, (S.shape, rows.shape, bk)
    return pl.pallas_call(
        _fold_cols_kernel,
        grid=(m // bk,),
        in_specs=[
            pl.BlockSpec((n, bk), lambda j: (0, j)),
            pl.BlockSpec((k, bk), lambda j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((n, k), lambda j: (0, 0)),
            pl.BlockSpec((k, k), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, k), jnp.float32),
            jax.ShapeDtypeStruct((k, k), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
        name="fold_cols_fused",
    )(S, rows)
