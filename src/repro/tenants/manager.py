"""``TenantManager`` — LRU residency for thousands of per-tenant deltas.

The registry that makes "millions of users" a memory-bounded statement:
each registered tenant owns a rank-r ``TenantDelta`` plus a per-tenant
``FoldJournal`` of its *projected* fold columns, and the manager keeps
only the hot set resident under an explicit byte budget. Three tiers:

* **hot** — delta resident *and* the materialized n×n tenant factor L_t
  cached, so a solve is a pure factor swap (zero per-request correction
  cost). The factor cache is keyed on the base state's maintenance
  counters (adapted / refreshes) + λ + the tenant's journal position, so
  any base fold, base refresh, λ change, or tenant fold rebuilds it.
* **warm** — delta resident (O(n·r) bytes), factor rebuilt on demand at
  O(n²·r) via ``delta_factor``.
* **spilled** — delta on disk in one npz (``checkpoint.fleet.
  save_tenant_spill``), zero bytes resident. Folds for a spilled tenant
  append to its journal without waking it; activation = load the npz +
  replay the journal tail (``events_since(applied)``) — bit-identical to
  never having evicted, because fold events store the already-projected
  dual columns (no S pass, no dependence on how the base window evolved
  since the spill).

Eviction is LRU over *resident* tenants whenever admitting or
materializing would cross ``budget_bytes``; every spill also compacts
the tenant's journal below the spilled seq (the npz covers that prefix —
the satellite compaction machinery exercised per-tenant). The journal's
projected rows are (k, n), not (k, m): tenant history is dual-sized.

The manager is deliberately single-process state (dicts + numpy/jax
arrays): in the fleet it lives inside one worker, and the consistent-
hash ``by_adapter`` placement guarantees a tenant's manager entries
never need to agree across workers.
"""
from __future__ import annotations

import pathlib
import tempfile
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.fleet import load_tenant_spill, save_tenant_spill
from repro.serve.journal import FoldJournal
from repro.serve.state import ServeState
from repro.tenants.delta import (TenantDelta, delta_factor, delta_fold,
                                 delta_nbytes, init_tenant_delta,
                                 project_rows)

__all__ = ["TenantManager", "TenantStats"]


class TenantStats:
    """Counters the manager exposes (heartbeats, benches). Plain ints —
    wire-safe through msgpack/json as a dict."""

    def __init__(self):
        self.activations = 0     # spill loads (restore + tail replay)
        self.evictions = 0       # residency drops (delta spilled to npz)
        self.materializations = 0  # factor (re)builds, O(n²·r) each
        self.factor_hits = 0     # solves served straight from a cached L_t

    def as_dict(self) -> dict:
        return {"activations": self.activations,
                "evictions": self.evictions,
                "materializations": self.materializations,
                "factor_hits": self.factor_hits}


class _Tenant:
    """One registry entry. ``delta`` is None exactly when spilled."""

    __slots__ = ("tid", "delta", "journal", "applied", "L", "factor_key",
                 "last_used", "served", "spill_path")

    def __init__(self, tid: str):
        self.tid = tid
        self.delta: Optional[TenantDelta] = None
        self.journal = FoldJournal()
        self.applied = 0          # journal seq folded into `delta`
        self.L: Optional[jax.Array] = None
        self.factor_key: Optional[Tuple] = None
        self.last_used = 0
        self.served = 0
        self.spill_path: Optional[pathlib.Path] = None

    @property
    def resident(self) -> bool:
        return self.delta is not None

    def nbytes(self) -> int:
        b = 0
        if self.delta is not None:
            b += delta_nbytes(self.delta)
        if self.L is not None:
            b += int(self.L.nbytes)
        return b


class TenantManager:
    """Registry + memory manager over one shared base ``ServeState``."""

    def __init__(self, rank: int, *, budget_bytes: Optional[int] = None,
                 spill_dir=None, registry=None):
        if rank < 1:
            raise ValueError("tenant rank budget must be >= 1")
        self.rank = int(rank)
        self.budget_bytes = None if budget_bytes is None else \
            int(budget_bytes)
        self.spill_dir = pathlib.Path(
            spill_dir if spill_dir is not None
            else tempfile.mkdtemp(prefix="tenant_spill_"))
        self.stats = TenantStats()
        # optional repro.obs.MetricsRegistry: occupancy gauges plus
        # evict/activate latency histograms (the residency tier's health)
        self.registry = registry
        self._tenants: Dict[str, _Tenant] = {}
        self._tick = 0            # LRU clock: bumped on every touch

    def __len__(self) -> int:
        return len(self._tenants)

    def __contains__(self, tid) -> bool:
        return str(tid) in self._tenants

    def tenants(self):
        return list(self._tenants)

    # -- registry ------------------------------------------------------------
    def _touch(self, t: _Tenant) -> None:
        self._tick += 1
        t.last_used = self._tick

    def _get(self, tid, *, create: bool, n: Optional[int] = None,
             dtype=None) -> _Tenant:
        tid = str(tid)
        t = self._tenants.get(tid)
        if t is None:
            if not create:
                raise KeyError(f"unknown tenant {tid!r}")
            t = _Tenant(tid)
            t.delta = init_tenant_delta(int(n), self.rank, dtype=dtype)
            self._tenants[tid] = t
            self._ensure_budget(exempt=tid)
        return t

    def delta(self, state: ServeState, tid) -> TenantDelta:
        """The tenant's resident delta (activating a spilled one)."""
        t = self._get(tid, create=True, n=state.L.shape[0],
                      dtype=state.L.dtype)
        self._activate(t)
        self._touch(t)
        return t.delta

    # -- folds ----------------------------------------------------------------
    def fold(self, state: ServeState, tid, rows, *, signs=None
             ) -> Tuple[int, ...]:
        """Fold tenant score rows (k, m): project through the resident
        base factor, journal the dual columns, and apply to the delta if
        the tenant is resident (a spilled tenant's folds accumulate in
        the journal and apply at activation — folding never wakes it).
        Returns the rank-budget slots written."""
        t = self._get(tid, create=True, n=state.L.shape[0],
                      dtype=state.L.dtype)
        Q = project_rows(state, rows)                      # (n, k)
        k = Q.shape[1]
        # the FIFO cursor is derivable without the delta: total folded
        # rows mod the rank budget (exactly TenantDelta.cursor's arithmetic)
        cursor = t.journal.total_k % self.rank
        slots = tuple((cursor + i) % self.rank for i in range(k))
        ev_rows = np.asarray(Q.T)                          # (k, n): dual-sized
        if signs is not None:
            ev_rows = np.concatenate(
                [ev_rows, np.asarray(signs, np.float32).reshape(k, 1)],
                axis=1)
        t.journal.append_fold(slots, ev_rows, origin=t.tid)
        if self.registry is not None:
            self.registry.counter("tenants.folds").inc()
            self.registry.counter("tenants.fold_rows").inc(k)
        if t.resident:
            t.delta, got = delta_fold(t.delta, Q, signs=signs)
            if got != slots:
                raise AssertionError(f"tenant {t.tid}: journal slots "
                                     f"{slots} != delta slots {got}")
            t.applied = t.journal.head
            t.L, t.factor_key = None, None     # factor is stale now
        self._touch(t)
        return slots

    def _apply_event(self, t: _Tenant, ev) -> None:
        rows = np.asarray(ev.rows)
        k = len(ev.slots)
        signs = None
        if rows.shape[1] == t.delta.cols.shape[0] + 1:   # signs rode along
            rows, signs = rows[:, :-1], rows[:, -1]
        t.delta, got = delta_fold(t.delta, jnp.asarray(rows.T), signs=signs)
        if got != tuple(ev.slots):
            raise AssertionError(
                f"tenant {t.tid}: replay of seq {ev.seq} landed in slots "
                f"{got}, journal says {tuple(ev.slots)}")

    # -- residency ------------------------------------------------------------
    def _activate(self, t: _Tenant) -> None:
        if t.resident:
            return
        t0 = time.perf_counter()
        arrays, meta = load_tenant_spill(t.spill_path)
        t.delta = TenantDelta(
            cols=jnp.asarray(arrays["cols"]),
            signs=jnp.asarray(arrays["signs"]),
            cursor=jnp.asarray(arrays["cursor"]),
            age=jnp.asarray(arrays["age"]))
        t.applied = int(meta["applied"])
        for ev in t.journal.events_since(t.applied):       # tail replay
            self._apply_event(t, ev)
        t.applied = t.journal.head
        self.stats.activations += 1
        if self.registry is not None:
            self.registry.counter("tenants.activations").inc()
            self.registry.histogram("tenants.activate_latency_s").observe(
                time.perf_counter() - t0)
            self._occupancy_gauges()
        self._ensure_budget(exempt=t.tid)

    def evict(self, tid) -> pathlib.Path:
        """Spill one tenant: delta → npz, drop it and any cached factor
        from RAM, compact its journal below the spilled seq."""
        t = self._get(tid, create=False)
        if not t.resident:
            return t.spill_path
        t0 = time.perf_counter()
        path = self.spill_dir / f"tenant_{t.tid}.npz"
        t.spill_path = save_tenant_spill(
            path,
            {"cols": np.asarray(t.delta.cols),
             "signs": np.asarray(t.delta.signs),
             "cursor": np.asarray(t.delta.cursor),
             "age": np.asarray(t.delta.age)},
            {"tenant": t.tid, "applied": t.applied, "rank": self.rank})
        t.delta, t.L, t.factor_key = None, None, None
        t.journal.compact(t.applied)       # the npz covers that prefix
        self.stats.evictions += 1
        if self.registry is not None:
            self.registry.counter("tenants.evictions").inc()
            self.registry.histogram("tenants.evict_latency_s").observe(
                time.perf_counter() - t0)
            self._occupancy_gauges()
        return t.spill_path

    def _ensure_budget(self, *, exempt: Optional[str] = None) -> None:
        if self.budget_bytes is None:
            return
        while self.resident_bytes() > self.budget_bytes:
            victims = [t for t in self._tenants.values()
                       if t.resident and t.tid != exempt]
            if not victims:
                return             # the exempt tenant alone may exceed it
            self.evict(min(victims, key=lambda t: t.last_used).tid)

    # -- the solve-path entry point -------------------------------------------
    def factor(self, state: ServeState, tid, *, lam=None) -> jax.Array:
        """The tenant's factor L_t at ``lam`` (default: the resident λ₀),
        activating and materializing as needed. This is what the servers
        swap in for ``state.L`` on a tenant microbatch."""
        t = self._get(tid, create=True, n=state.L.shape[0],
                      dtype=state.L.dtype)
        self._activate(t)
        lam_v = float(state.lam0) if lam is None else float(lam)
        key = (int(state.stats.adapted), int(state.stats.refreshes),
               lam_v, t.applied)
        if t.L is not None and t.factor_key == key:
            self.stats.factor_hits += 1
        else:
            base_L = state.L
            if lam is not None and lam_v != float(state.lam0):
                eye = jnp.eye(state.W.shape[0], dtype=state.W.dtype)
                base_L = jnp.linalg.cholesky(state.W + lam_v * eye)
            if self.registry is not None:
                # the rank-r core eigenvalues are computed for the
                # correction anyway — gauge their conditioning (worst
                # across tenants wins: max-merged via the condest suffix)
                t.L, cond = delta_factor(t.delta, base_L, lam_v,
                                         return_cond=True)
                cond_v = float(cond)
                prev = self.registry.gauge(
                    "tenants.delta_core_condest").value
                self.registry.gauge("tenants.delta_core_condest").set(
                    max(prev, cond_v))
            else:
                t.L = delta_factor(t.delta, base_L, lam_v)
            t.factor_key = key
            self.stats.materializations += 1
            if self.registry is not None:
                self.registry.counter("tenants.materializations").inc()
            self._ensure_budget(exempt=t.tid)
        t.served += 1
        self._touch(t)
        if self.registry is not None:
            self._occupancy_gauges()
        return t.L

    def _occupancy_gauges(self) -> None:
        """Hot/warm/spilled occupancy into the registry (hot = factor
        cached; warm = delta resident, factor not)."""
        reg = self.registry
        hot = sum(1 for t in self._tenants.values()
                  if t.resident and t.L is not None)
        resident = self.resident_count()
        reg.gauge("tenants.registered").set(len(self._tenants))
        reg.gauge("tenants.hot").set(hot)
        reg.gauge("tenants.warm").set(resident - hot)
        reg.gauge("tenants.spilled").set(len(self._tenants) - resident)
        reg.gauge("tenants.resident_bytes").set(self.resident_bytes())

    # -- accounting ------------------------------------------------------------
    def resident_bytes(self) -> int:
        return sum(t.nbytes() for t in self._tenants.values())

    def resident_count(self) -> int:
        return sum(t.resident for t in self._tenants.values())

    def packing_stats(self, *, top: int = 4) -> dict:
        """Wire-safe summary for fleet heartbeats: residency, budget
        pressure, and the hottest tenants by solves served."""
        hot = sorted(self._tenants.values(), key=lambda t: -t.served)[:top]
        return {"tenants": len(self._tenants),
                "resident": self.resident_count(),
                "spilled": len(self._tenants) - self.resident_count(),
                "resident_bytes": self.resident_bytes(),
                "budget_bytes": self.budget_bytes,
                "hot": {t.tid: t.served for t in hot if t.served},
                **self.stats.as_dict()}
