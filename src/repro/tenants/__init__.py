"""Multi-tenant curvature platform: one shared base factor, per-tenant
rank-r deltas, LRU residency.

The serving stack (``repro.serve`` → ``repro.dist`` → ``repro.fleet``)
maintains one resident window/factor per process; this package makes
that one state serve *thousands of tenants*: each tenant is a rank-r
dual-space delta over the shared base (``delta`` — the algebra) managed
under a byte budget with spill-to-disk residency (``manager`` — the
memory model). Servers accept ``tenant=`` on submit, the batcher
coalesces per-tenant microbatches, and the fleet's consistent-hash
``by_adapter`` routing becomes tenant placement.
"""
from repro.tenants.delta import (TenantDelta, augmented_window,
                                 delta_correction, delta_factor, delta_fold,
                                 delta_nbytes, init_tenant_delta,
                                 project_rows, tenant_factorization)
from repro.tenants.manager import TenantManager, TenantStats

__all__ = [
    "TenantDelta",
    "init_tenant_delta",
    "project_rows",
    "delta_fold",
    "delta_correction",
    "delta_factor",
    "tenant_factorization",
    "augmented_window",
    "delta_nbytes",
    "TenantManager",
    "TenantStats",
]
