"""``TenantDelta`` — one tenant's curvature as a rank-r dual-space delta.

The multi-tenant memory model: every tenant shares ONE resident base
``ServeState`` (window S, Gram W, factor L — maintained exactly as
today), and owns only r dual-space delta columns. A tenant's effective
curvature is the shared window *reweighted* in dual space,

    F_t = λ·I + Sᵀ·(Ĩ + P·diag(s)·P†)·S,        P : (n, r), s ∈ {±1, 0},

i.e. the private window ``[S; P†S]`` (the base samples plus r projected
tenant samples) without ever materializing its O(n·m) rows. The solve
stays the paper's dual identity with a rank-r corrected factor: writing
M = Ĩ + P·diag(s)·P†, the Woodbury push-through gives

    F_t⁻¹ v = (v − Sᵀ·w)/λ,     (W + λ·M⁻¹)·w = S·v,

and  W + λM⁻¹ = (W + λĨ) − λ·P·(diag(s)⁻¹ + P†P)⁻¹·P†  — the base damped
Gram minus a rank-r Hermitian form. ``signed_split`` of its r×r core
turns the tenant factor into one ``chol_update`` + one ``chol_downdate``
of the *base* L at O(n²·r) (``delta_factor``), or equivalently one
``CholFactorization.update``/``.downdate`` pair (``tenant_factorization``).
Both S passes of the solve touch only the shared window — a tenant
microbatch runs the same fused serve kernel as a base microbatch with
L_t swapped in — so the resident per-tenant cost is exactly the delta:
O(n·r) bytes, independent of m. (Note the dual inversion: a tenant that
*adds* curvature, s = +1, *downdates* the dual factor — λM⁻¹ ⪯ λĨ.)

A tenant fold projects the tenant's score rows onto the shared window's
row space through the resident factor (``project_rows``: one O(n·m·k)
S pass + triangular solves — the ridge projection q = (W+λ₀Ĩ)⁻¹·S·g†,
so the folded sample is P†S's best representation of g) and FIFO-writes
the resulting dual columns into the fixed rank budget (``delta_fold``),
retiring the tenant's oldest delta columns exactly like the base window
retires samples. Folds are pure, fixed-shape functions of the stored
columns — replaying the same projected columns reproduces the delta (and
therefore the factor) bit for bit, which is what the manager's
spill/activate path (``repro.tenants.manager``) relies on.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular

from repro.core.operator import is_blocked
from repro.core.solvers import CholFactorization
from repro.curvature.update import chol_downdate, chol_update
from repro.serve.state import ServeState, as_factorization, serve_mode

__all__ = ["TenantDelta", "init_tenant_delta", "project_rows", "delta_fold",
           "delta_correction", "delta_factor", "tenant_factorization",
           "augmented_window", "delta_nbytes"]

_HI = jax.lax.Precision.HIGHEST
_EMPTY = 1e30          # core eigenvalue sentinel for unfilled budget slots


class TenantDelta(NamedTuple):
    """One tenant's resident state (a pytree; checkpoints like any other).

    ``cols``: the (n, r) dual-space delta columns P — zero where the rank
    budget slot is unfilled. ``signs``: (r,) in {+1, −1, 0}: +1 adds the
    projected sample's curvature, −1 subtracts it (down-weighting shared
    behaviour), 0 marks an empty slot. ``cursor``: next FIFO slot in the
    rank budget. ``age``: folds applied since creation.
    """
    cols: jax.Array
    signs: jax.Array
    cursor: jax.Array
    age: jax.Array

    @property
    def rank(self) -> int:
        return self.cols.shape[1]

    @property
    def filled(self) -> jax.Array:
        return jnp.sum((self.signs != 0).astype(jnp.int32))


def init_tenant_delta(n: int, rank: int, *, dtype=jnp.float32) -> TenantDelta:
    """An empty delta: the tenant solves exactly like the base until its
    first fold. ``rank`` is the tenant's whole memory budget — r ≪ m."""
    if rank < 1:
        raise ValueError("tenant rank budget must be >= 1")
    return TenantDelta(cols=jnp.zeros((n, rank), dtype),
                       signs=jnp.zeros((rank,), jnp.float32),
                       cursor=jnp.zeros((), jnp.int32),
                       age=jnp.zeros((), jnp.int32))


def _sv_pass(S, rows, *, mode: str) -> jax.Array:
    """u = S·rows† (n, k): the one m-sized pass of a tenant fold."""
    row_blocks = tuple(rows) if isinstance(rows, (tuple, list)) else (rows,)
    S_blocks = S.blocks if is_blocked(S) else (S,)
    acc = jnp.promote_types(S_blocks[0].dtype, jnp.float32)

    def one(b, r):
        r = jnp.asarray(r)
        if r.ndim == 1:
            r = r[None, :]
        rt = r.conj().T if mode == "complex" else r.T
        return jnp.matmul(b.astype(acc), rt.astype(acc), precision=_HI)

    return sum(one(b, r) for b, r in zip(S_blocks, row_blocks))


def project_rows(state: ServeState, rows, *, jitter: float = 0.0
                 ) -> jax.Array:
    """Project tenant score rows (k, m) — dense or per-block pieces — into
    dual space through the resident base factor:

        Q = (W + λ₀Ĩ)⁻¹ · S·rows†  =  L⁻†·L⁻¹·(S·rows†)        (n, k)

    The ridge projection of each row onto the shared window's row space:
    folding Q gives the tenant the curvature of the projected samples
    Q†S, the closest window-representable stand-in for its raw rows. The
    columns are what the tenant journals — replay needs no S pass."""
    del jitter  # the resident L already carries the server's jitter
    mode = serve_mode(state)
    u = _sv_pass(state.S, rows, mode=mode)
    L = state.L.astype(jnp.promote_types(state.L.dtype, u.dtype))
    q = solve_triangular(L, u.astype(L.dtype), lower=True)
    ct = L.conj().T if mode == "complex" else L.T
    return solve_triangular(ct, q, lower=False)


def delta_fold(delta: TenantDelta, Q, *, signs=None
               ) -> Tuple[TenantDelta, Tuple[int, ...]]:
    """FIFO-write k projected columns into the rank budget; returns
    (delta', slots) with ``slots`` the budget positions written — the
    tenant-journal analogue of the window's fold slots. Pure and fixed-
    shape: replaying the same columns reproduces the delta bit for bit."""
    Q = jnp.asarray(Q)
    if Q.ndim == 1:
        Q = Q[:, None]
    n, k = Q.shape
    r = delta.rank
    if k > r:
        raise ValueError(f"cannot fold {k} columns into a rank-{r} budget")
    if Q.shape[0] != delta.cols.shape[0]:
        raise ValueError(f"delta columns have {delta.cols.shape[0]} rows, "
                         f"fold has {Q.shape[0]}")
    s = jnp.ones((k,), jnp.float32) if signs is None \
        else jnp.asarray(signs, jnp.float32).reshape(k)
    cursor = int(delta.cursor)
    slots = tuple((cursor + i) % r for i in range(k))
    idx = jnp.asarray(slots, jnp.int32)
    cols = delta.cols.at[:, idx].set(Q.astype(delta.cols.dtype))
    return delta._replace(cols=cols,
                          signs=delta.signs.at[idx].set(s),
                          cursor=jnp.asarray((cursor + k) % r, jnp.int32),
                          age=delta.age + 1), slots


def delta_correction(delta: TenantDelta, lam, *, return_cond: bool = False):
    """The signed factor correction at damping ``lam``: (up, down) with

        (W + λĨ) + up·up† − down·down†  =  W + λ·M⁻¹,

    i.e. ``L_t = chol_downdate(chol_update(L, up), down)``. Derived from
    the r×r core  diag(s)⁻¹ + P†P  (empty slots pinned at a huge positive
    eigenvalue, so their columns scale to exactly zero). All-(+1) deltas
    produce a pure downdate — adding tenant curvature shrinks λM⁻¹.

    ``return_cond=True`` appends the conditioning of the *live* core
    spectrum (max |ev| / min |ev| over genuine delta directions, 1.0 for
    an empty delta) — the eigenvalues are computed here anyway, so the
    health gauge is free."""
    P = delta.cols
    r = delta.rank
    s = delta.signs.astype(P.real.dtype)
    # diag(s)^-1 with empty slots at _EMPTY: their eigenpairs decouple
    # (P column is zero there) and the 1/sqrt scale flushes to ~0
    d_inv = jnp.where(s == 0, _EMPTY, jnp.where(s < 0, -1.0, 1.0))
    core = jnp.diag(d_inv).astype(P.dtype) + jnp.matmul(
        P.conj().T, P, precision=_HI)
    core = (core + core.conj().T) / 2
    ev, V = jnp.linalg.eigh(core)
    lam = jnp.real(jnp.asarray(lam, P.real.dtype))
    live = jnp.abs(ev) < (_EMPTY / 1e6)          # genuine delta directions
    scale = jnp.where(live,
                      jnp.sqrt(lam / jnp.maximum(jnp.abs(ev), 1e-30)), 0.0)
    C = jnp.matmul(P, V, precision=_HI) * scale[None, :]
    up = jnp.where(ev < 0, 1.0, 0.0)[None, :] * C     # chol_update columns
    down = jnp.where(ev > 0, 1.0, 0.0)[None, :] * C   # chol_downdate columns
    if return_cond:
        a = jnp.real(jnp.abs(ev))
        mx = jnp.max(jnp.where(live, a, 0.0))
        mn = jnp.min(jnp.where(live, a, jnp.inf))
        cond = jnp.where(jnp.isfinite(mn) & (mx > 0),
                         mx / jnp.maximum(mn, 1e-30), 1.0)
        return up, down, cond
    return up, down


def delta_factor(delta: TenantDelta, L: jax.Array, lam, *,
                 method: str = "composed", return_cond: bool = False):
    """The tenant's resident-λ factor from the base factor: O(n²·r).

    ``L`` must be the base chol(W + λĨ) at the same ``lam``; hot tenants
    cache the result (``TenantManager``), cold tenants recompute on
    demand. An empty delta returns a factor equal to L.
    ``return_cond=True``: also return the live core conditioning (see
    ``delta_correction``) as ``(L_t, cond)``."""
    if return_cond:
        up, down, cond = delta_correction(delta, lam, return_cond=True)
        return chol_downdate(chol_update(L, up, method=method), down,
                             method=method), cond
    up, down = delta_correction(delta, lam)
    return chol_downdate(chol_update(L, up, method=method), down,
                         method=method)


def tenant_factorization(state: ServeState, delta: TenantDelta, *,
                         jitter: float = 0.0, lam=None,
                         L: Optional[jax.Array] = None) -> CholFactorization:
    """The tenant's view of the shared window as a first-class solver.

    Built through ``CholFactorization.update``/``.downdate`` (S kept —
    the delta never touches the window), so every solver affordance
    (multi-RHS ``solve``, monitored residuals) applies to the tenant.
    ``lam`` re-dampens from the cached W first (the tenant mixed-λ path);
    ``L`` short-circuits the O(n²·r) correction with a cached factor."""
    fac = as_factorization(state, jitter=jitter)
    if lam is not None and float(lam) != float(state.lam0):
        fac = fac.with_damping(lam)
    if L is not None:
        return fac._replace(S=fac.S, W=fac.W, L=L)
    up, down = delta_correction(delta, fac.lam)
    return fac.update(up, S_new=fac.S).downdate(down, S_new=fac.S)


def augmented_window(state: ServeState, delta: TenantDelta):
    """The tenant's *private window* ``[S; P†S]`` — the O((n+r)·m) state
    the delta replaces. Only the from-scratch reference path (tests,
    ``benchmarks/serve_tenants.py``) ever materializes it; requires an
    all-(+1) dense delta (a down-weighting column is not a window row)."""
    if is_blocked(state.S):
        raise NotImplementedError("reference window: dense S only")
    if bool(jnp.any(delta.signs < 0)):
        raise ValueError("negative-sign delta has no window equivalent")
    P = delta.cols
    mode = serve_mode(state)
    Pt = P.conj().T if mode == "complex" else P.T
    S = state.S.astype(jnp.promote_types(state.S.dtype, P.dtype))
    extra = jnp.matmul(Pt.astype(S.dtype), S, precision=_HI)
    return jnp.concatenate([S, extra], axis=0)


def delta_nbytes(delta: TenantDelta) -> int:
    """Resident bytes of the delta — the O(n·r) the platform is for."""
    return sum(int(leaf.nbytes) for leaf in jax.tree.leaves(delta))
