"""Exact damped NGD on an over-parameterized MLP (the paper's regime:
m ≫ n) vs AdamW — loss per optimizer step.

    PYTHONPATH=src python examples/ngd_mlp_train.py [--big]

Default: m ≈ 90k params, n = 256 samples (seconds on CPU).
--big:    m ≈ 1.1M params (the paper's 10⁶ scale).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamW, NaturalGradient, per_sample_scores

ap = argparse.ArgumentParser()
ap.add_argument("--big", action="store_true")
ap.add_argument("--steps", type=int, default=30)
args = ap.parse_args()

d_in, width = (64, 512) if args.big else (32, 128)
n = 256
rng = np.random.default_rng(0)
key = jax.random.key(0)

params = {
    "w1": jnp.asarray(rng.normal(size=(d_in, width)) / d_in**0.5, jnp.float32),
    "b1": jnp.zeros((width,), jnp.float32),
    "w2": jnp.asarray(rng.normal(size=(width, width)) / width**0.5, jnp.float32),
    "b2": jnp.zeros((width,), jnp.float32),
    "w3": jnp.asarray(rng.normal(size=(width, 1)) / width**0.5, jnp.float32),
}
m = sum(x.size for x in jax.tree_util.tree_leaves(params))
print(f"m = {m:,} parameters, n = {n} samples  (m/n = {m / n:.0f})")

X = jnp.asarray(rng.normal(size=(n, d_in)), jnp.float32)
y_true = jnp.sin(3 * X[:, :1]).sum(-1) + 0.5 * jnp.cos(X[:, 1])


def predict(p, x):
    h = jnp.tanh(x @ p["w1"] + p["b1"])
    h = jnp.tanh(h @ p["w2"] + p["b2"])
    return (h @ p["w3"])[..., 0]


def loss(p):
    return jnp.mean((predict(p, X) - y_true) ** 2)


# Damped least squares / Levenberg-Marquardt (paper §3): the score rows are
# the per-sample RESIDUAL Jacobian J_i = ∂r_i/∂θ, so (SᵀS + λI) is the
# damped Gauss-Newton metric and Algorithm 1 solves the LM step exactly.
def sample_obj(p, ex):
    x, y = ex
    return predict(p, x[None])[0] - y          # residual r_i


@jax.jit
def ngd_step(p, opt_state, lam):
    g = jax.grad(lambda q: 0.5 * loss(q))(p)   # ∇(½ MSE) = Jᵀr/n
    S = per_sample_scores(sample_obj, p, (X, y_true))
    return opt_ngd.update(g, opt_state, p, scores=S)


opt_ngd = NaturalGradient(1.0, damping=1e-3, momentum=0.0)
opt_adam = AdamW(1e-2, weight_decay=0.0)


def run(kind):
    p = jax.tree.map(jnp.copy, params)
    hist = [float(loss(p))]
    st = (opt_ngd if kind == "ngd" else opt_adam).init(p)
    for _ in range(args.steps):
        if kind == "ngd":
            upd, st = ngd_step(p, st, 1e-3)
        else:
            upd, st = opt_adam.update(jax.grad(loss)(p), st, p)
        p = jax.tree.map(jnp.add, p, upd)
        hist.append(float(loss(p)))
    return hist


t0 = time.perf_counter()
h_ngd = run("ngd")
t_ngd = time.perf_counter() - t0
t0 = time.perf_counter()
h_adam = run("adam")
t_adam = time.perf_counter() - t0

print(f"{'step':>5s} {'NGD(chol)':>12s} {'AdamW':>12s}")
for s in range(0, args.steps + 1, max(args.steps // 10, 1)):
    print(f"{s:5d} {h_ngd[s]:12.5f} {h_adam[s]:12.5f}")
print(f"\nNGD reaches {h_ngd[-1]:.5f} in {args.steps} steps "
      f"({t_ngd:.1f}s); AdamW reaches {h_adam[-1]:.5f} ({t_adam:.1f}s)")
assert h_ngd[-1] < h_adam[-1], "NGD should win per-step on this problem"
