"""Serving example: batched prefill + greedy decode **plus online
natural-gradient adaptation** through the serving subsystem.

The pre-serve-subsystem version of this example only decoded; it now
drives `repro.serve` end to end: a resident curvature window is
factorized once, requests coalesce through the token-budget batcher, the
`SolveServer` answers each with a damped-Fisher solve off the cached
factor (per-request λ included — no Gram on the request path), updates
are applied to the live params, and each request's score rows fold back
into the window via the rank-k algebra before its response is decoded.

    PYTHONPATH=src python examples/serve_lm.py [--arch gemma2-2b] [--new 8]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None, emit=print):
    from repro import configs
    from repro.launch.mesh import make_mesh
    from repro.launch.trainer import build_server

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--window", type=int, default=6)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--new", type=int, default=8, help="tokens decoded")
    ap.add_argument("--damping", type=float, default=1e-2)
    ap.add_argument("--lr", type=float, default=0.02)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch)
    mesh = make_mesh((1, 1), ("data", "model"))

    t0 = time.perf_counter()
    server, h = build_server(cfg, mesh=mesh, window=args.window,
                             seq=args.seq, damping=args.damping,
                             max_tokens=4 * args.seq, max_requests=4)
    emit(f"window factorized: n={args.window} m={server.state.S.shape[1]} "
         f"({(time.perf_counter() - t0) * 1e3:.0f} ms)")

    results = {}
    for r in range(args.requests):
        ex = jax.tree.map(lambda x: x[:2], h.data.batch_at(r + 1))
        loss, v, rows = h.score_grads(h.params, ex)
        uid = server.submit(v, tokens=2 * args.seq, rows=rows,
                            payload=ex["inputs"][:1])
        results[uid] = float(loss)

    for res in server.flush():
        h.apply_update(res.x, lr=args.lr)
        emit(f"req {res.uid} loss {results[res.uid]:.4f} "
             f"solve {res.latency_s * 1e3:.1f} ms")

    # decode the last request's prompt with the adapted params
    prompt = jnp.asarray(h.data.batch_at(args.requests)["inputs"][:1,
                                                                  :args.seq])
    t0 = time.perf_counter()
    gen = h.decode(prompt, new_tokens=args.new)
    dt = time.perf_counter() - t0
    emit(f"decoded {args.new} tokens in {dt * 1e3:.0f} ms "
         f"({dt / max(args.new, 1) * 1e3:.1f} ms/tok)")
    emit(f"sample token ids: {np.asarray(gen[0][:12]).tolist()}")

    s = server.metrics.summary()
    emit(f"served {s['served']}: p50 {s['p50_ms']:.1f} ms "
         f"p99 {s['p99_ms']:.1f} ms ({s['rps']:.1f} req/s)")
    return server, s


if __name__ == "__main__":
    main()
