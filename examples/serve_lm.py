"""Serving example: batched prefill + greedy decode through the sharded
serve step (the same code path the decode_32k / long_500k dry-run cells
lower for the production mesh).

    PYTHONPATH=src python examples/serve_lm.py [--arch gemma2-2b] [--new 24]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import train as T
from repro.launch.mesh import make_mesh
from repro.models.api import get_api

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="gemma2-2b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=32)
ap.add_argument("--new", type=int, default=24)
args = ap.parse_args()

cfg = configs.get_smoke(args.arch)
api = get_api(cfg)
mesh = make_mesh((1, 1), ("data", "model"))
params = api.init_params(jax.random.key(0))

rng = np.random.default_rng(0)
prompt = jnp.asarray(rng.integers(0, cfg.vocab,
                                  (args.batch, args.prompt_len)))
max_len = args.prompt_len + args.new

t0 = time.perf_counter()
logits, cache, idx = api.prefill(params, {"tokens": prompt,
                                          "max_len": max_len})
print(f"prefill({args.batch}×{args.prompt_len}) "
      f"{(time.perf_counter() - t0) * 1e3:.0f} ms")

ispecs = {"tokens": jax.ShapeDtypeStruct((args.batch, 1), jnp.int32),
          "cache": jax.eval_shape(lambda: cache),
          "cache_index": jax.ShapeDtypeStruct((), jnp.int32)}
serve, _ = T.jit_serve_step(api, mesh,
                            param_specs=jax.eval_shape(lambda: params),
                            input_specs=ispecs, donate=False)

tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
generated = [tok]
t0 = time.perf_counter()
for t in range(args.new - 1):
    nxt, cache = serve(params, cache, jnp.asarray(args.prompt_len + t),
                       generated[-1])
    generated.append(nxt[:, None])
dt = time.perf_counter() - t0
gen = jnp.concatenate(generated, axis=1)
print(f"decoded {args.new - 1} tokens/stream in {dt * 1e3:.0f} ms "
      f"({dt / max(args.new - 1, 1) * 1e3:.1f} ms/tok)")
print("sample token ids:", np.asarray(gen[0][:12]))
