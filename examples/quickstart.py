"""Quickstart: the paper's Algorithm 1 in five lines.

    PYTHONPATH=src python examples/quickstart.py

Solves (SᵀS + λI)x = v for m ≫ n without ever forming the m×m Fisher
matrix, checks the residual, and compares against the two SVD baselines.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chol_solve, eigh_solve, svd_solve, residual

n, m, lam = 512, 100_000, 1e-2   # κ(F) ≈ ‖S‖²/λ ≈ 2e4 → fp32 residual ~1e-3
rng = np.random.default_rng(0)
S = jnp.asarray(rng.normal(size=(n, m)) / np.sqrt(n), jnp.float32)
v = jnp.asarray(rng.normal(size=(m,)), jnp.float32)

for name, solver in [("chol (Algorithm 1)", chol_solve),
                     ("eigh (Appendix C)", eigh_solve),
                     ("svd  (Appendix C)", svd_solve)]:
    fn = jax.jit(lambda S, v, _f=solver: _f(S, v, lam))
    x = jax.block_until_ready(fn(S, v))          # compile + run
    t0 = time.perf_counter()
    x = jax.block_until_ready(fn(S, v))
    dt = time.perf_counter() - t0
    print(f"{name:20s} {dt * 1e3:8.1f} ms   "
          f"relative residual {float(residual(S, v, x, lam)):.2e}")
