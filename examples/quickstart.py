"""Quickstart: the paper's Algorithm 1 in five lines.

    PYTHONPATH=src python examples/quickstart.py

Solves (SᵀS + λI)x = v for m ≫ n without ever forming the m×m Fisher
matrix, checks the residual, compares against the two SVD baselines, and
shows the streaming-curvature cache amortizing repeat solves.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chol_solve, eigh_solve, svd_solve, residual
from repro.curvature import CurvatureCache, StreamingCurvature


def main(n=512, m=100_000, lam=1e-2, steps=3, emit=print):
    # κ(F) ≈ ‖S‖²/λ ≈ 2e4 → fp32 residual ~1e-3
    rng = np.random.default_rng(0)
    S = jnp.asarray(rng.normal(size=(n, m)) / np.sqrt(n), jnp.float32)
    v = jnp.asarray(rng.normal(size=(m,)), jnp.float32)

    results = {}
    for name, solver in [("chol (Algorithm 1)", chol_solve),
                         ("eigh (Appendix C)", eigh_solve),
                         ("svd  (Appendix C)", svd_solve)]:
        fn = jax.jit(lambda S, v, _f=solver: _f(S, v, lam))
        x = jax.block_until_ready(fn(S, v))          # compile + run
        t0 = time.perf_counter()
        x = jax.block_until_ready(fn(S, v))
        dt = time.perf_counter() - t0
        r = float(residual(S, v, x, lam))
        results[name.split()[0]] = (dt, r)
        emit(f"{name:20s} {dt * 1e3:8.1f} ms   relative residual {r:.2e}")

    # streaming curvature: the O(n²m) Gram runs once, repeat solves reuse it
    cache = CurvatureCache(StreamingCurvature(n, refresh_every=steps + 1))
    for s in range(steps):
        t0 = time.perf_counter()
        x = jax.block_until_ready(cache.solve(S, v, lam))
        dt = time.perf_counter() - t0
        tag = "refresh" if s == 0 else "cache hit"
        emit(f"curvature cache ({tag})  {dt * 1e3:8.1f} ms   "
             f"relative residual {float(residual(S, v, x, lam)):.2e}")
    stats = cache.stats
    emit(f"curvature cache stats: {int(stats.hits)} hits / "
         f"{int(stats.refreshes)} refreshes")
    results["cache"] = (int(stats.hits), int(stats.refreshes))
    return results


if __name__ == "__main__":
    main()
