"""End-to-end driver: train an LM with the paper's damped NGD for a few
hundred steps, with checkpointing and restart supervision — the trainer CLI
in library form.

    PYTHONPATH=src python examples/lm_ngd_train.py \
        [--arch llama3.2-3b] [--steps 300] [--optimizer ngd]

Uses the reduced (smoke) config so the run completes on CPU; the exact same
code path drives the full configs on a pod (see launch/dryrun.py for the
compile-time proof).
"""
import argparse

from repro.launch.trainer import train_main

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="llama3.2-3b")
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--optimizer", default="ngd", choices=["ngd", "adamw"])
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=64)
args = ap.parse_args()

losses, report = train_main([
    "--arch", args.arch, "--smoke",
    "--optimizer", args.optimizer,
    "--steps", str(args.steps),
    "--batch", str(args.batch),
    "--seq", str(args.seq),
    "--ckpt-dir", "artifacts/ckpt_example",
    "--log-every", "25",
])
print(f"trained {args.steps} steps; loss {losses[0]:.3f} → {losses[-1]:.3f};"
      f" restarts={report['restarts']}")
