"""Stochastic reconfiguration (paper §3): complex wavefunction, both Fisher
conventions.

A toy variational state |ψ_θ⟩ over 12 spins with complex parameters is
optimized toward a target state by SR: S is the centered complex score
matrix, and the update solves (F + λI)δ = -∇E with

  * full complex Fisher  F = S†S   (mode="complex")
  * real-part Fisher     F = Re[S†S]  via S ← [Re S; Im S]  (mode="real_part")

    PYTHONPATH=src python examples/sr_complex.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import center_scores, chol_solve

L = 10                     # spins → 2^10 amplitudes (exact summation)
rng = np.random.default_rng(0)
key = jax.random.key(0)

basis = jnp.asarray(
    ((np.arange(2 ** L)[:, None] >> np.arange(L)) & 1) * 2.0 - 1.0,
    jnp.float32)                                   # (2^L, L) spins ±1
feats = jnp.concatenate(
    [basis, basis * jnp.roll(basis, 1, axis=1),
     basis * jnp.roll(basis, 2, axis=1),
     jnp.ones((2 ** L, 1))], axis=1)               # (2^L, P)
P = feats.shape[1]         # complex parameters (m = P ≫ n is NOT needed
                           # here — this demo is about the SR modes)

target = jax.random.normal(jax.random.key(42), (P,), jnp.float32) * 0.3


def log_psi(theta, f):
    return jnp.sum(theta * f)                      # log-linear ansatz


def energy(theta):
    """⟨ψ|H|ψ⟩ with H = -|t⟩⟨t| for the normalized target state t."""
    logp = jax.vmap(lambda f: log_psi(theta, f))(feats)
    logp = logp - jax.scipy.special.logsumexp(2 * jnp.real(logp)) / 2
    psi = jnp.exp(logp)
    logt = jax.vmap(lambda f: log_psi(target + 0j, f))(feats)
    logt = logt - jax.scipy.special.logsumexp(2 * jnp.real(logt)) / 2
    t = jnp.exp(logt)
    return -jnp.abs(jnp.vdot(t, psi)) ** 2


theta = (jax.random.normal(key, (P,)) * 0.1
         + 1j * jax.random.normal(jax.random.key(1), (P,)) * 0.1)


@jax.jit
def sr_step_complex(th):
    logp = jax.vmap(lambda f: jnp.real(log_psi(th, f)))(feats)
    w = jax.nn.softmax(2 * logp)
    S = center_scores(feats.astype(jnp.complex64), weights=w)
    g = jax.grad(lambda t: jnp.real(energy(t)))(th)       # C→R cotangent
    delta = chol_solve(S, jnp.conj(g), 1e-3, mode="complex")
    return th - 0.5 * delta


@jax.jit
def sr_step_real_part(th):
    logp = jax.vmap(lambda f: jnp.real(log_psi(th, f)))(feats)
    w = jax.nn.softmax(2 * logp)
    S = center_scores(feats.astype(jnp.complex64), weights=w)
    g = jax.grad(lambda t: jnp.real(energy(t)))(th)
    delta = chol_solve(S, jnp.real(g), 1e-3, mode="real_part")
    return th - 0.5 * delta.astype(jnp.complex64)


for mode, step in (("complex", sr_step_complex),
                   ("real_part", sr_step_real_part)):
    th = theta.astype(jnp.complex64)
    for it in range(50):
        th = step(th)
    print(f"SR mode={mode:10s} final overlap energy "
          f"{float(energy(th)):+.4f} (perfect = -1.0, start "
          f"{float(energy(theta.astype(jnp.complex64))):+.4f})")
